//! Property-based tests (hand-rolled: proptest is unavailable offline).
//! Each property runs against many seeded-random cases; failures print
//! the seed for reproduction.
//!
//! Invariants covered:
//!   * DCOH single-writer / writer-excludes-readers under random op mixes;
//!   * switch routing: route(addr) is the unique attached owner;
//!   * log region: a persistent (emb, mlp) pair survives any crash point,
//!     and rollback restores exactly the pre-batch table;
//!   * media model: duration monotone in access count; RAW never helps;
//!   * relaxed-lookup commutativity: early lookup + correction == strict
//!     dependent lookup (the paper's Fig-8 equivalence), in exact f32;
//!   * pipeline: every config/model pair conserves time (breakdown==batch)
//!     and produces non-overlapping spans per serial lane;
//!   * workload: per-tier stats sum to the per-table counts, shard
//!     striping conserves global counts for arbitrary shard counts, and
//!     `hot_hit_frac` stays in [0, 1] at the cache-size extremes;
//!   * tenancy: every arbiter policy's schedule serves every tenant its
//!     exact batch quota for arbitrary tenant counts/weights (pool slots
//!     are conserved — policies reorder service, never create/destroy
//!     it), and fair-share never lets a tenant wait more than one round;
//!   * latency histogram: every reported percentile lands in the same
//!     log bucket as the exact nearest-rank value (and never below it),
//!     and merge(a, b) is indistinguishable from recording the union;
//!   * span log: `busy(lane, from, to)` (the overlap-merged sweep behind
//!     every utilization figure) equals a brute-force per-ns oracle for
//!     arbitrary overlapping/nested/duplicated spans and windows.

use trainingcxl::config::device::DeviceParams;
use trainingcxl::config::ModelConfig;
use trainingcxl::emb::EmbeddingStore;
use trainingcxl::repo_root;
use trainingcxl::sim::cxl::dcoh::AgentId;
use trainingcxl::sim::cxl::{Dcoh, PortId, Switch};
use trainingcxl::sim::mem::{AccessKind, MediaKind, MediaModel};
use trainingcxl::util::Rng;
use trainingcxl::workload::Generator;

const CASES: u64 = 200;

#[test]
fn prop_dcoh_invariants_hold_under_random_ops() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let mut d = Dcoh::new();
        for _ in 0..200 {
            let agent = AgentId(rng.gen_range(4) as u16);
            let addr = rng.gen_range(64) * 64;
            match rng.gen_range(3) {
                0 => d.read(agent, addr),
                1 => d.write(agent, addr),
                _ => {
                    let _ = d.flush_line(agent, addr);
                }
            }
            d.check_invariants()
                .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        }
    }
}

#[test]
fn prop_dcoh_flush_returns_line_iff_modified() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xD0C);
        let mut d = Dcoh::new();
        let agent = AgentId(1);
        let addr = rng.gen_range(1024) * 64;
        if rng.gen_range(2) == 0 {
            d.write(agent, addr);
            assert_eq!(d.flush_line(agent, addr).unwrap(), 64, "seed {seed}");
        } else {
            d.read(agent, addr);
            assert_eq!(d.flush_line(agent, addr).unwrap(), 0, "seed {seed}");
        }
    }
}

#[test]
fn prop_switch_routes_to_unique_owner() {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x51);
        let mut sw = Switch::new();
        // random non-overlapping windows laid out sequentially
        let mut base = 0u64;
        let mut windows = Vec::new();
        for p in 0..4u16 {
            base += rng.gen_range(1024) + 1; // gap
            let len = rng.gen_range(4096) + 64;
            sw.attach(PortId(p), &format!("dev{p}"), base, len).unwrap();
            windows.push((base, len, p));
            base += len;
        }
        for _ in 0..100 {
            let addr = rng.gen_range(base + 1024);
            let expect = windows
                .iter()
                .find(|(s, l, _)| addr >= *s && addr < s + l)
                .map(|(_, _, p)| PortId(*p));
            assert_eq!(sw.route(addr).ok(), expect, "seed {seed} addr {addr}");
        }
    }
}

#[test]
fn prop_log_region_always_recoverable_after_first_generation() {
    let root = repo_root();
    let cfg = ModelConfig::load(&root, "rm_mini").unwrap();
    for seed in 0..50 {
        let mut rng = Rng::new(seed ^ 0x106);
        let mut store = EmbeddingStore::zeros(&cfg);
        for t in 0..cfg.num_tables {
            for r in 0..cfg.rows_per_table {
                store.row_mut(t, r).fill(rng.next_f32());
            }
        }
        let mut region = trainingcxl::checkpoint::LogRegion::new();
        let mut pre_batch_images: Vec<EmbeddingStore> = Vec::new();

        for batch in 0..6u64 {
            // random touched set
            let mut touched = Vec::new();
            for _ in 0..(rng.gen_range(8) + 1) {
                touched.push((
                    rng.gen_range(cfg.num_tables as u64) as usize,
                    rng.gen_range(cfg.rows_per_table as u64) as usize,
                ));
            }
            touched.sort_unstable();
            touched.dedup();
            pre_batch_images.push(store.clone());
            region.begin_emb_log(batch, &store, &touched);
            region.seal_emb_log(batch);
            region.begin_mlp_log(batch, &[vec![batch as f32]]);
            region.advance_mlp_log(4);
            region.seal_mlp_log();
            // apply a random "update" to the touched rows
            for &(t, r) in &touched {
                store.row_mut(t, r).fill(rng.next_f32() + 1.0);
            }
            // crash now: recovery must restore exactly the pre-batch image
            let mut crashed = store.clone();
            let rec = trainingcxl::checkpoint::recover(&mut crashed, &region)
                .unwrap_or_else(|e| panic!("seed {seed} batch {batch}: {e}"));
            assert_eq!(rec.resume_batch, batch, "seed {seed}");
            assert_eq!(
                &crashed, &pre_batch_images[batch as usize],
                "seed {seed} batch {batch}: rollback mismatch"
            );
        }
    }
}

#[test]
fn prop_media_duration_monotone_in_access_count() {
    let p = DeviceParams::builtin_default();
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x3E);
        for (kind, mp) in [
            (MediaKind::Dram, &p.dram),
            (MediaKind::Pmem, &p.pmem),
            (MediaKind::Ssd, &p.ssd),
        ] {
            let n1 = rng.gen_range(100_000) + 1;
            let n2 = n1 + rng.gen_range(100_000) + 1;
            let ak = if rng.gen_range(2) == 0 {
                AccessKind::Read
            } else {
                AccessKind::Write
            };
            let mut m1 = MediaModel::new(kind, mp.clone());
            let mut m2 = MediaModel::new(kind, mp.clone());
            let d1 = m1.batch_access(0, n1, 128, ak, 0.0).duration;
            let d2 = m2.batch_access(0, n2, 128, ak, 0.0).duration;
            assert!(d2 >= d1, "seed {seed} {kind:?} {ak:?}: {n2}>{n1} but {d2}<{d1}");
        }
    }
}

#[test]
fn prop_raw_never_speeds_up_reads() {
    let p = DeviceParams::builtin_default();
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xAA);
        let n = rng.gen_range(50_000) + 100;
        let frac = rng.next_f64();
        let gap = rng.gen_range(2 * p.pmem.raw_window_ns);
        let mut clean = MediaModel::new(MediaKind::Pmem, p.pmem.clone());
        let base = clean.batch_access(0, n, 128, AccessKind::Read, 0.0).duration;
        let mut dirty = MediaModel::new(MediaKind::Pmem, p.pmem.clone());
        let w = dirty.batch_access(0, 1000, 128, AccessKind::Write, 0.0);
        let raw = dirty
            .batch_access(w.duration + gap, n, 128, AccessKind::Read, frac)
            .duration;
        assert!(raw >= base, "seed {seed}: RAW read faster than clean");
    }
}

#[test]
fn prop_relaxed_lookup_commutes_exactly() {
    // Fig 8: lookup(T_old) + correction == lookup(T_new), exact in f32
    // when the correction adds the same delta rows (addition commutes up
    // to association order — we apply deltas in identical order).
    let root = repo_root();
    let cfg = ModelConfig::load(&root, "rm_mini").unwrap();
    for seed in 0..100 {
        let mut rng = Rng::new(seed ^ 0xF18);
        let mut table = EmbeddingStore::zeros(&cfg);
        for t in 0..cfg.num_tables {
            for r in 0..cfg.rows_per_table {
                table.row_mut(t, r).fill(rng.next_f32());
            }
        }
        // batch-N update: delta on random rows
        let mut deltas: Vec<(usize, usize, f32)> = Vec::new();
        for _ in 0..8 {
            deltas.push((
                rng.gen_range(cfg.num_tables as u64) as usize,
                rng.gen_range(cfg.rows_per_table as u64) as usize,
                rng.next_f32() - 0.5,
            ));
        }
        // batch-N+1 lookup rows
        let lookups: Vec<(usize, usize)> = (0..16)
            .map(|_| {
                (
                    rng.gen_range(cfg.num_tables as u64) as usize,
                    rng.gen_range(cfg.rows_per_table as u64) as usize,
                )
            })
            .collect();

        // dependent schedule: apply update, then lookup
        let mut updated = table.clone();
        for &(t, r, d) in &deltas {
            for v in updated.row_mut(t, r) {
                *v += d;
            }
        }
        let dependent: Vec<f32> = lookups
            .iter()
            .flat_map(|&(t, r)| updated.row(t, r).to_vec())
            .collect();

        // relaxed schedule: lookup old table, then add the delta for rows
        // the lookup touched (same add order as the update applied)
        let mut early: Vec<f32> = lookups
            .iter()
            .flat_map(|&(t, r)| table.row(t, r).to_vec())
            .collect();
        for (i, &(lt, lr)) in lookups.iter().enumerate() {
            for &(t, r, d) in &deltas {
                if (t, r) == (lt, lr) {
                    for v in &mut early[i * cfg.feature_dim..(i + 1) * cfg.feature_dim] {
                        *v += d;
                    }
                }
            }
        }
        assert_eq!(early, dependent, "seed {seed}: relaxation changed numerics");
    }
}

#[test]
fn prop_per_tier_stats_sum_to_table_stats() {
    let root = repo_root();
    let cfg = ModelConfig::load(&root, "rm_mini").unwrap();
    for seed in 0..60 {
        let mut rng = Rng::new(seed ^ 0x71E2);
        let cache = rng.next_f64() * 0.5;
        let hot = rng.next_f64();
        let mut g = Generator::new(&cfg, seed)
            .with_cache_frac(cache)
            .with_hot_tier_frac(hot);
        let _ = g.next_batch(); // warm: overlap + carried tier classification
        let b = g.next_batch();
        let (mut hot_acc, mut hot_uni, mut hot_ov) = (0u64, 0u64, 0u64);
        for ts in &b.table_stats {
            assert!(ts.hot_tier_hits <= ts.accesses, "seed {seed}");
            assert!(ts.hot_tier_unique <= ts.unique_rows, "seed {seed}");
            assert!(ts.hot_tier_overlap_hits <= ts.overlap_hits, "seed {seed}");
            assert!(ts.hot_tier_overlap_hits <= ts.hot_tier_hits, "seed {seed}");
            // the clamp fix: resident hits are distinct per access
            assert!(ts.cache_resident_hits <= ts.accesses, "seed {seed}");
            assert!(ts.cache_resident_hits >= ts.overlap_hits, "seed {seed}");
            assert!(
                ts.cache_resident_hits <= ts.cache_hits + ts.overlap_hits,
                "seed {seed}"
            );
            hot_acc += ts.hot_tier_hits;
            hot_uni += ts.hot_tier_unique;
            hot_ov += ts.hot_tier_overlap_hits;
        }
        // per-tier table counts fold exactly into the batch aggregates
        assert_eq!(b.stats.hot_accesses, hot_acc, "seed {seed}");
        assert_eq!(b.stats.hot_unique_rows, hot_uni, "seed {seed}");
        assert_eq!(b.stats.hot_overlap_hits, hot_ov, "seed {seed}");
        assert!(b.stats.hot_accesses <= b.stats.accesses, "seed {seed}");
        assert!(b.stats.hot_unique_rows <= b.stats.unique_rows, "seed {seed}");
    }
}

#[test]
fn prop_shard_striping_conserves_global_counts() {
    let root = repo_root();
    let cfg = ModelConfig::load(&root, "rm_mini").unwrap();
    for seed in 0..40 {
        let mut rng = Rng::new(seed ^ 0x5A4D);
        let shards = (rng.gen_range(16) + 1) as usize;
        let mut g = Generator::new(&cfg, seed)
            .with_cache_frac(0.1)
            .with_hot_tier_frac(0.3);
        let _ = g.next_batch(); // warm
        let b = g.next_batch();
        let per = g.shard_stats(&b, shards);
        assert_eq!(per.len(), shards, "seed {seed}");
        let sum = |f: fn(&trainingcxl::workload::BatchStats) -> u64| -> u64 {
            per.iter().map(f).sum()
        };
        assert_eq!(sum(|s| s.accesses), b.stats.accesses, "seed {seed}/{shards}");
        assert_eq!(sum(|s| s.unique_rows), b.stats.unique_rows, "seed {seed}/{shards}");
        assert_eq!(sum(|s| s.hot_accesses), b.stats.hot_accesses, "seed {seed}/{shards}");
        assert_eq!(
            sum(|s| s.hot_unique_rows),
            b.stats.hot_unique_rows,
            "seed {seed}/{shards}"
        );
        assert_eq!(
            sum(|s| s.hot_overlap_hits),
            b.stats.hot_overlap_hits,
            "seed {seed}/{shards}"
        );
        // fraction fields stay fractions on every stripe, and the
        // access-weighted overlap folds back to the global count
        let mut weighted_ov = 0.0;
        for s in &per {
            assert!((0.0..=1.0).contains(&s.prev_overlap), "seed {seed}/{shards}");
            assert!((0.0..=1.0).contains(&s.hot_hit_frac), "seed {seed}/{shards}");
            weighted_ov += s.prev_overlap * s.accesses as f64;
        }
        let global_ov = b.stats.prev_overlap * b.stats.accesses as f64;
        assert!(
            (weighted_ov - global_ov).abs() < 1e-6,
            "seed {seed}/{shards}: {weighted_ov} vs {global_ov}"
        );
    }
}

#[test]
fn prop_hot_hit_frac_bounded_at_cache_extremes() {
    let root = repo_root();
    let cfg = ModelConfig::load(&root, "rm_mini").unwrap();
    for seed in 0..30 {
        // cache_rows == logical_rows: after the distinct-count fix every
        // access is resident — exactly 1.0, no clamp needed
        let mut full = Generator::new(&cfg, seed).with_cache_frac(1.0);
        let _ = full.next_batch();
        assert_eq!(full.next_batch().stats.hot_hit_frac, 1.0, "seed {seed}");
        // cache_rows == 0: exactly 0.0
        let mut none = Generator::new(&cfg, seed).with_cache_frac(0.0);
        let _ = none.next_batch();
        assert_eq!(none.next_batch().stats.hot_hit_frac, 0.0, "seed {seed}");
        // anything in between stays a true fraction
        let mut mid = Generator::new(&cfg, seed).with_cache_frac(seed as f64 / 30.0);
        let _ = mid.next_batch();
        let f = mid.next_batch().stats.hot_hit_frac;
        assert!((0.0..=1.0).contains(&f), "seed {seed}: {f}");
    }
}

#[test]
fn prop_arbiter_schedules_conserve_pool_slots() {
    // "Fair-share conserves total pool cycles": the arbiter's schedule
    // contains exactly `batches` service slots per tenant — for ANY
    // tenant count and weight vector, under every policy, nothing is
    // created, dropped, or double-served. Fair-share additionally bounds
    // starvation: within every round of n consecutive slots each tenant
    // is served exactly once.
    use trainingcxl::tenancy::{PoolArbiter, QosPolicy};
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x7E47);
        let n = rng.gen_range(12) as usize + 1;
        let batches = rng.gen_range(20) + 1;
        let weights: Vec<u64> = (0..n).map(|_| rng.gen_range(5) + 1).collect();
        for policy in [
            QosPolicy::FairShare,
            QosPolicy::Weighted,
            QosPolicy::StrictPriority,
        ] {
            let arb = PoolArbiter::new(policy, weights.clone()).unwrap();
            let order = arb.schedule(batches);
            assert_eq!(
                order.len() as u64,
                n as u64 * batches,
                "seed {seed} {policy:?}: slots not conserved"
            );
            let mut served = vec![0u64; n];
            for &i in &order {
                assert!(i < n, "seed {seed} {policy:?}: unknown tenant {i}");
                served[i] += 1;
            }
            assert!(
                served.iter().all(|&s| s == batches),
                "seed {seed} {policy:?}: uneven service {served:?}"
            );
            if policy == QosPolicy::FairShare {
                for (r, round) in order.chunks(n).enumerate() {
                    let mut seen = vec![false; n];
                    for &i in round {
                        assert!(!seen[i], "seed {seed}: tenant {i} served twice in round {r}");
                        seen[i] = true;
                    }
                }
            }
        }
    }
}

#[test]
fn prop_latency_histogram_percentiles_within_one_bucket() {
    use trainingcxl::telemetry::LatencyHistogram;
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x4157);
        let n = rng.gen_range(400) as usize + 1;
        let mut h = LatencyHistogram::new();
        let mut vals = Vec::with_capacity(n);
        for _ in 0..n {
            // span many magnitudes: sub-us lookups to minute-long tails
            let mag = rng.gen_range(40);
            let v = (1u64 << mag) + rng.gen_range(1u64 << mag);
            h.record(v);
            vals.push(v);
        }
        vals.sort_unstable();
        assert_eq!(h.count(), n as u64, "seed {seed}");
        assert_eq!(h.min(), vals[0], "seed {seed}");
        assert_eq!(h.max(), *vals.last().unwrap(), "seed {seed}");
        for q in [0.5, 0.99, 0.999] {
            let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
            let exact = vals[rank - 1];
            let approx = h.percentile(q);
            // the histogram walks to the exact value's bucket, then
            // reports its upper bound clamped to the observed max: the
            // estimate can never undershoot the exact percentile and
            // never overshoot by more than the bucket's width
            let (_, hi) = LatencyHistogram::bucket_bounds(LatencyHistogram::bucket_index(exact));
            assert!(
                approx >= exact && approx <= hi,
                "seed {seed} q={q}: exact {exact} (bucket hi {hi}) vs approx {approx}"
            );
        }
    }
}

#[test]
fn prop_latency_histogram_merge_equals_union() {
    use trainingcxl::telemetry::LatencyHistogram;
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0x6E11);
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        let mut union = LatencyHistogram::new();
        for _ in 0..rng.gen_range(300) {
            let mag = rng.gen_range(48);
            let v = (1u64 << mag) + rng.gen_range(1u64 << mag);
            if rng.gen_range(2) == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            union.record(v);
        }
        a.merge(&b);
        assert_eq!(a, union, "seed {seed}: merge != recording the union");
    }
}

#[test]
fn prop_span_log_busy_matches_per_ns_oracle() {
    use trainingcxl::sim::{Lane, OpKind};
    use trainingcxl::telemetry::SpanLog;
    const LANES: [Lane; 3] = [Lane::Gpu, Lane::Pmem, Lane::Link];
    // a tiny coordinate range forces heavy overlap, nesting, duplicates,
    // zero-length spans, and windows that clip span edges
    const RANGE: u64 = 64;
    for seed in 0..CASES {
        let mut rng = Rng::new(seed ^ 0xB0_5F);
        let mut log = SpanLog::default();
        for _ in 0..rng.gen_range(24) {
            let lane = LANES[rng.gen_range(3) as usize];
            let start = rng.gen_range(RANGE);
            let end = start + rng.gen_range(RANGE / 4);
            log.add(lane, OpKind::Idle, 0, start, end);
        }
        let from = rng.gen_range(RANGE);
        let to = from + rng.gen_range(RANGE);
        for lane in LANES {
            // oracle: count every ns instant in [from, to) covered by
            // any span of this lane
            let mut oracle = 0u64;
            for t in from..to {
                let covered = log
                    .spans
                    .iter()
                    .any(|s| s.lane == lane && s.start <= t && t < s.end);
                if covered {
                    oracle += 1;
                }
            }
            let got = log.busy(lane, from, to);
            assert_eq!(got, oracle, "seed {seed} {lane:?} [{from}, {to})");
        }
        // a degenerate (empty) window reports zero busy time
        assert_eq!(log.busy(Lane::Gpu, to, to), 0, "seed {seed}: empty window");
    }
}

#[test]
fn prop_pipeline_time_conservation_random_configs() {
    use trainingcxl::bench::experiments;
    use trainingcxl::config::SystemConfig;
    let root = repo_root();
    for seed in 0..24 {
        let mut rng = Rng::new(seed);
        let model = ["rm1", "rm3", "rm_mini"][rng.gen_range(3) as usize];
        let sys = SystemConfig::ALL[rng.gen_range(6) as usize];
        let n = rng.gen_range(6) + 3;
        let r = experiments::simulate(&root, model, sys, n).unwrap();
        for (bd, bt) in r.breakdowns.iter().zip(&r.batch_times) {
            let bt = *bt as f64;
            assert!(
                (bd.total() - bt).abs() <= 0.03 * bt + 10.0,
                "seed {seed} {model}/{}: {} vs {}",
                sys.name(),
                bd.total(),
                bt
            );
        }
        // serial-lane spans must not overlap (GPU, CompLogic)
        for lane in [trainingcxl::sim::Lane::Gpu, trainingcxl::sim::Lane::CompLogic] {
            let mut spans: Vec<_> = r
                .spans
                .spans
                .iter()
                .filter(|s| s.lane == lane)
                .map(|s| (s.start, s.end))
                .collect();
            spans.sort_unstable();
            for w in spans.windows(2) {
                assert!(
                    w[0].1 <= w[1].0,
                    "seed {seed} {model}/{}: overlapping {lane:?} spans {w:?}",
                    sys.name()
                );
            }
        }
    }
}
