//! Deterministic PRNG (xoshiro256**) and a bounded Zipf sampler.
//!
//! Everything in the simulator and workload generator must be reproducible
//! from a seed; xoshiro256** is the same generator family the `rand_xoshiro`
//! crate ships and passes BigCrush. The Zipf sampler uses the
//! rejection-inversion method of Hörmann & Derflinger (1996) — the same
//! algorithm as `rand_distr::Zipf` — so table-access skew matches what the
//! paper models from Criteo Kaggle.

/// xoshiro256** by Blackman & Vigna, seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed, as recommended by the authors.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Unbiased uniform integer in [0, n) (Lemire's method).
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Box-Muller.
    pub fn next_normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fork an independent stream (for per-component determinism).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

/// Bounded Zipf(n, a) sampler by rejection inversion; values in [0, n).
#[derive(Clone, Debug)]
pub struct Zipf {
    n: f64,
    a: f64,
    h_x1: f64,
    h_n: f64,
    s: f64,
}

impl Zipf {
    pub fn new(n: u64, a: f64) -> Self {
        assert!(n >= 1 && a > 0.0 && (a - 1.0).abs() > 1e-9, "a != 1 required");
        let n = n as f64;
        let h = |x: f64| ((1.0 - a) * x.ln()).exp() / (1.0 - a) * x / x; // placeholder
        let _ = h;
        let hf = |x: f64| (x.powf(1.0 - a)) / (1.0 - a);
        Zipf {
            n,
            a,
            h_x1: hf(1.5) - 1.0,
            h_n: hf(n + 0.5),
            s: 2.0 - Self::h_inv_static(a, hf(2.5) - 2.0f64.powf(-a)),
        }
    }

    fn h_inv_static(a: f64, x: f64) -> f64 {
        ((1.0 - a) * x).powf(1.0 / (1.0 - a))
    }

    fn h(&self, x: f64) -> f64 {
        x.powf(1.0 - self.a) / (1.0 - self.a)
    }

    fn h_inv(&self, x: f64) -> f64 {
        ((1.0 - self.a) * x).powf(1.0 / (1.0 - self.a))
    }

    /// Draw one rank in [0, n); rank 0 is the hottest row.
    pub fn sample(&self, rng: &mut Rng) -> u64 {
        loop {
            let u = self.h_n + rng.next_f64() * (self.h_x1 - self.h_n);
            let x = self.h_inv(u);
            let k = (x + 0.5).floor().clamp(1.0, self.n);
            if k - x <= self.s || u >= self.h(k + 0.5) - k.powf(-self.a) {
                return k as u64 - 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_range_bounds_and_mean() {
        let mut r = Rng::new(7);
        let n = 100u64;
        let mut sum = 0u64;
        for _ in 0..20_000 {
            let v = r.gen_range(n);
            assert!(v < n);
            sum += v;
        }
        let mean = sum as f64 / 20_000.0;
        assert!((mean - 49.5).abs() < 1.5, "mean {mean}");
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn zipf_is_skewed_and_bounded() {
        let z = Zipf::new(1000, 1.05);
        let mut r = Rng::new(11);
        let mut counts = vec![0u32; 1000];
        for _ in 0..50_000 {
            let v = z.sample(&mut r) as usize;
            assert!(v < 1000);
            counts[v] += 1;
        }
        // hottest rank dominates the median rank by a wide margin
        assert!(counts[0] > 20 * counts[500].max(1));
        // and the head (top 1%) holds a disproportionate share
        let head: u32 = counts[..10].iter().sum();
        assert!(head as f64 > 0.2 * 50_000.0 * 0.1, "head {head}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let xs: Vec<f64> = (0..50_000).map(|_| r.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
