//! Memory media timing models (paper Table 2): DRAM, Optane-like PMEM with
//! read-after-write interference, and NAND SSD with GC write amplification.
//!
//! Each medium is parameterised by per-access latency, per-channel
//! bandwidth, channel count and queue depth. [`MediaModel::batch_access`]
//! is the closed-form cost the batch pipeline uses; [`controller`] is the
//! request-level discrete-event ground truth it is validated against
//! (`tests::analytic_matches_request_level`).
//!
//! RAW (read-after-write) interference: Optane reads that land shortly
//! after writes to the same region are slowed by internal write-buffer
//! (XPBuffer) flushes — the phenomenon (9)/BIBIM describes and the paper's
//! *relaxed embedding lookup* eliminates. The model keeps the end time of
//! the last write burst; reads issued within `raw_window_ns` pay
//! `raw_mult` on their latency component for the overlapping fraction.

pub mod controller;

use super::SimTime;
use crate::config::device::MediaParams;

/// Which medium (for energy accounting and debug).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MediaKind {
    Dram,
    Pmem,
    Ssd,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccessKind {
    Read,
    Write,
}

/// Outcome of a batch of accesses: duration plus accounting the energy
/// model consumes.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct AccessCost {
    pub duration: SimTime,
    pub bytes_read: u64,
    pub bytes_written: u64,
    /// Accesses that paid the RAW penalty (telemetry / ablations).
    pub raw_hits: u64,
}

/// Stateful analytic media model.
#[derive(Clone, Debug)]
pub struct MediaModel {
    pub kind: MediaKind,
    pub p: MediaParams,
    /// End time of the most recent write burst (RAW window anchor).
    last_write_end: SimTime,
}

impl MediaModel {
    pub fn new(kind: MediaKind, p: MediaParams) -> Self {
        MediaModel {
            kind,
            p,
            last_write_end: 0,
        }
    }

    /// Reset inter-batch state (fresh run).
    pub fn reset(&mut self) {
        self.last_write_end = 0;
    }

    fn lat_ns(&self, kind: AccessKind) -> f64 {
        match kind {
            AccessKind::Read => self.p.read_ns,
            AccessKind::Write => self.p.write_ns,
        }
    }

    fn bw_gbps(&self, kind: AccessKind) -> f64 {
        match kind {
            AccessKind::Read => self.p.read_gbps,
            AccessKind::Write => self.p.write_gbps,
        }
    }

    /// Closed-form duration of `n` independent accesses of `bytes_each`,
    /// issued at `start`, spread over the channels.
    ///
    /// Per-channel service time of one access is
    /// `max(bytes/bw, latency/queue_depth)` — latency pipelines up to the
    /// queue depth, bandwidth never oversubscribes — plus one full latency
    /// to fill the pipe. `raw_frac` of reads pay `raw_mult` on the latency
    /// component when issued inside the RAW window.
    pub fn batch_access(
        &mut self,
        start: SimTime,
        n: u64,
        bytes_each: u64,
        kind: AccessKind,
        raw_frac: f64,
    ) -> AccessCost {
        if n == 0 {
            return AccessCost::default();
        }
        let mut lat = self.lat_ns(kind);
        let mut raw_hits = 0u64;
        if kind == AccessKind::Read && self.p.raw_mult > 1.0 && raw_frac > 0.0 {
            // XPBuffer writeback pressure decays as the device drains: the
            // penalty is strongest immediately after a write burst and
            // fades linearly across the RAW window.
            let gap = start.saturating_sub(self.last_write_end) as f64;
            let strength = (1.0 - gap / self.p.raw_window_ns.max(1) as f64).max(0.0);
            if strength > 0.0 {
                lat *= 1.0 + raw_frac * (self.p.raw_mult - 1.0) * strength;
                raw_hits = (n as f64 * raw_frac * strength) as u64;
            }
        }
        let write_amp = if kind == AccessKind::Write {
            self.p.write_amp.max(1.0)
        } else {
            1.0
        };
        let eff_bytes = bytes_each as f64 * write_amp;
        let per_chan_bw_ns_per_byte = 1.0 / self.bw_gbps(kind); // ns per byte at 1GB/s = 1ns/B
        let service = (eff_bytes * per_chan_bw_ns_per_byte)
            .max(lat / self.p.queue_depth as f64);
        let per_chan = (n as f64 / self.p.channels as f64).ceil();
        let duration = super::ns(lat + per_chan * service);
        let end = start + duration;
        if kind == AccessKind::Write {
            self.last_write_end = self.last_write_end.max(end);
        }
        let total_bytes = n * bytes_each;
        AccessCost {
            duration,
            bytes_read: if kind == AccessKind::Read { total_bytes } else { 0 },
            bytes_written: if kind == AccessKind::Write {
                (total_bytes as f64 * write_amp) as u64
            } else {
                0
            },
            raw_hits,
        }
    }

    /// Duration of one sequential stream of `bytes` (checkpoint logs, model
    /// dumps): latency + bytes at full aggregate bandwidth.
    pub fn stream(&mut self, start: SimTime, bytes: u64, kind: AccessKind) -> AccessCost {
        if bytes == 0 {
            return AccessCost::default();
        }
        let write_amp = if kind == AccessKind::Write {
            // streams are sequential: no GC amplification
            1.0
        } else {
            1.0
        };
        let agg_bw = self.bw_gbps(kind) * self.p.channels as f64;
        let duration = super::ns(self.lat_ns(kind) + bytes as f64 * write_amp / agg_bw);
        let end = start + duration;
        if kind == AccessKind::Write {
            self.last_write_end = self.last_write_end.max(end);
        }
        AccessCost {
            duration,
            bytes_read: if kind == AccessKind::Read { bytes } else { 0 },
            bytes_written: if kind == AccessKind::Write { bytes } else { 0 },
            raw_hits: 0,
        }
    }

    /// True if a read starting at `t` would be inside the RAW window.
    pub fn in_raw_window(&self, t: SimTime) -> bool {
        t < self.last_write_end.saturating_add(self.p.raw_window_ns)
    }

    pub fn last_write_end(&self) -> SimTime {
        self.last_write_end
    }
}

#[cfg(test)]
mod tests {
    use super::controller::{Controller, Request};
    use super::*;
    use crate::config::device::DeviceParams;

    fn params() -> DeviceParams {
        DeviceParams::builtin_default()
    }

    #[test]
    fn table2_latency_ratios() {
        let p = params();
        // Table 2: PMEM read 3x, write 7x DRAM; SSD 165x.
        assert!((p.pmem.read_ns / p.dram.read_ns - 3.0).abs() < 0.01);
        assert!((p.pmem.write_ns / p.dram.write_ns - 7.0).abs() < 0.01);
        assert!((p.ssd.read_ns / p.dram.read_ns - 165.0).abs() < 0.01);
        // bandwidth: 0.6x / 0.1x / 0.02x
        assert!((p.pmem.read_gbps / p.dram.read_gbps - 0.6).abs() < 0.01);
        assert!((p.pmem.write_gbps / p.dram.write_gbps - 0.1).abs() < 0.01);
        assert!((p.ssd.read_gbps / p.dram.read_gbps - 0.02).abs() < 0.01);
    }

    #[test]
    fn pmem_slower_than_dram_and_raw_slower_still() {
        let p = params();
        let mut dram = MediaModel::new(MediaKind::Dram, p.dram.clone());
        let mut pmem = MediaModel::new(MediaKind::Pmem, p.pmem.clone());
        let d = dram.batch_access(0, 10_000, 128, AccessKind::Read, 0.0);
        let m = pmem.batch_access(0, 10_000, 128, AccessKind::Read, 0.0);
        assert!(m.duration > d.duration);

        // write then read immediately: RAW kicks in
        let w = pmem.batch_access(0, 10_000, 128, AccessKind::Write, 0.0);
        let raw = pmem.batch_access(w.duration, 10_000, 128, AccessKind::Read, 0.8);
        assert!(raw.duration > m.duration, "{} vs {}", raw.duration, m.duration);
        assert!(raw.raw_hits > 0);

        // penalty decays with distance from the write burst
        let half = pmem.last_write_end() + pmem.p.raw_window_ns / 2;
        let mid = pmem.batch_access(half, 10_000, 128, AccessKind::Read, 0.8);
        assert!(mid.duration < raw.duration && mid.duration > m.duration);

        // read past the window: no penalty
        let later = pmem.last_write_end() + pmem.p.raw_window_ns + 1;
        let clean = pmem.batch_access(later, 10_000, 128, AccessKind::Read, 0.8);
        assert_eq!(clean.duration, m.duration);
        assert_eq!(clean.raw_hits, 0);
    }

    #[test]
    fn ssd_small_random_reads_are_catastrophic() {
        let p = params();
        let mut ssd = MediaModel::new(MediaKind::Ssd, p.ssd.clone());
        let mut pmem = MediaModel::new(MediaKind::Pmem, p.pmem.clone());
        let s = ssd.batch_access(0, 100_000, 128, AccessKind::Read, 0.0);
        let m = pmem.batch_access(0, 100_000, 128, AccessKind::Read, 0.0);
        // paper: PMEM is orders of magnitude faster on embedding gathers
        assert!(s.duration > 50 * m.duration, "{} vs {}", s.duration, m.duration);
    }

    #[test]
    fn write_amplification_counted() {
        let p = params();
        let mut ssd = MediaModel::new(MediaKind::Ssd, p.ssd.clone());
        let c = ssd.batch_access(0, 100, 128, AccessKind::Write, 0.0);
        assert!(c.bytes_written > 100 * 128);
    }

    #[test]
    fn stream_faster_than_random_for_same_bytes() {
        let p = params();
        let mut pmem = MediaModel::new(MediaKind::Pmem, p.pmem.clone());
        let total = 1_000_000u64;
        let random = pmem.batch_access(0, total / 128, 128, AccessKind::Write, 0.0);
        pmem.reset();
        let stream = pmem.stream(0, total, AccessKind::Write);
        assert!(stream.duration <= random.duration);
    }

    #[test]
    fn analytic_matches_request_level() {
        // The closed-form batch model must track the event-driven
        // controller within 15% across media and access kinds.
        let p = params();
        for (kind, mp) in [
            (MediaKind::Dram, p.dram.clone()),
            (MediaKind::Pmem, p.pmem.clone()),
        ] {
            for ak in [AccessKind::Read, AccessKind::Write] {
                let mut analytic = MediaModel::new(kind, mp.clone());
                let a = analytic.batch_access(0, 5000, 128, ak, 0.0);
                let mut ctrl = Controller::new(mp.clone());
                let reqs: Vec<Request> = (0..5000)
                    .map(|i| Request {
                        addr: i * 128,
                        bytes: 128,
                        kind: ak,
                    })
                    .collect();
                let d = ctrl.run_batch(&reqs);
                let ratio = a.duration as f64 / d as f64;
                assert!(
                    (0.85..=1.15).contains(&ratio),
                    "{kind:?}/{ak:?}: analytic {} vs DES {d} (ratio {ratio:.3})",
                    a.duration
                );
            }
        }
    }

    #[test]
    fn zero_accesses_cost_nothing() {
        let p = params();
        let mut m = MediaModel::new(MediaKind::Dram, p.dram.clone());
        assert_eq!(m.batch_access(0, 0, 128, AccessKind::Read, 0.0).duration, 0);
        assert_eq!(m.stream(0, 0, AccessKind::Write).duration, 0);
    }
}
