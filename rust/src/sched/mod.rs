//! Batch pipeline scheduling — the paper's system contribution.
//!
//! [`pipeline::PipelineSim`] composes the device timing oracles into the
//! per-configuration training pipelines of Fig 4/6/8/9b/12: software
//! (SSD/PMEM), near-data PCIe, and the three TrainingCXL stages (CXL-D,
//! CXL-B, CXL). [`pipeline::RunResult`] carries spans (Fig 12),
//! critical-path breakdowns (Fig 11), and traffic counters (Fig 13).

pub mod pipeline;

pub use pipeline::{PipelineSim, RunResult};
