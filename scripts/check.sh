#!/usr/bin/env bash
# Tier-1 verification: rust build+tests, python tests.
# Usage: scripts/check.sh [--rust-only|--python-only|--bench-smoke]
#
# --bench-smoke runs the CI smoke sweep instead of the test tiers: the
# shard-scaling, tier-sweep, tenant-interference, serve-latency,
# fault-sweep, and engine-throughput sweeps plus one figure experiment,
# all at reduced iterations, with Report JSON written under
# artifacts/bench-smoke/
# (the CI job uploads that directory as a workflow artifact). The binary
# itself fails on experiment errors, empty reports, or non-finite
# metrics (Experiment::run's gates); engine-throughput drops
# BENCH_engine.json at the repo root and asserts byte-identical results
# across worker counts, tenant-interference drops BENCH_tenancy.json,
# and fault-sweep drops BENCH_faults.json — all three must exist and
# parse as JSON. The sweep then exports one Perfetto trace per shipped
# topology family via `trainingcxl trace` (which schema-validates the
# TraceLog before writing: orphaned parents, inverted spans, or slots
# escaping their round fail the command).
set -euo pipefail
cd "$(dirname "$0")/.."

want_rust=1
want_python=1
want_bench=0
case "${1:-}" in
  --rust-only) want_python=0 ;;
  --python-only) want_rust=0 ;;
  --bench-smoke) want_rust=0; want_python=0; want_bench=1 ;;
  "") ;;
  *) echo "usage: scripts/check.sh [--rust-only|--python-only|--bench-smoke]" >&2; exit 2 ;;
esac

status=0

# Build the rm_mini AOT artifacts when the python toolchain can (jax
# importable): the rust train::failure / runtime_e2e tests self-skip
# without them, so this is what turns them on in CI. Idempotent — aot.py
# fingerprints its sources and skips up-to-date artifacts. Only worth the
# compile time when the rust tier will actually run (cargo present).
if [ "$want_rust" = 1 ] && command -v cargo >/dev/null 2>&1; then
  if command -v python3 >/dev/null 2>&1 && python3 -c "import jax" >/dev/null 2>&1; then
    echo "== building rm_mini artifacts (python -m compile.aot) =="
    (cd python && python3 -m compile.aot --model rm_mini)
  else
    echo "!! jax not importable: skipping artifact build (artifact-gated rust tests will self-skip)" >&2
  fi
fi

if [ "$want_rust" = 1 ]; then
  if command -v cargo >/dev/null 2>&1; then
    echo "== cargo build --release =="
    cargo build --release
    echo "== cargo test -q =="
    cargo test -q
    # Hard gate: the static crash-consistency analyzer must find every
    # shipped topology TOML, the exhaustive builder-family enumeration,
    # and the mixed tenant worlds free of violations (warnings pass).
    echo "== static crash-consistency analyzer (trainingcxl analyze) =="
    cargo run --release --quiet -- analyze
  else
    echo "!! cargo not found: skipping rust tier (install a rust toolchain)" >&2
    status=0 # informational skip; CI images provide the toolchain
  fi
fi

if [ "$want_python" = 1 ]; then
  if command -v python3 >/dev/null 2>&1; then
    echo "== python -m pytest python/tests -q =="
    python3 -m pytest python/tests -q
  else
    echo "!! python3 not found: skipping python tier" >&2
  fi
fi

if [ "$want_bench" = 1 ]; then
  if command -v cargo >/dev/null 2>&1; then
    out=artifacts/bench-smoke
    mkdir -p "$out"
    echo "== bench smoke: shard-scaling (reduced iterations) =="
    cargo run --release --quiet -- bench shard-scaling --batches 6 --json > "$out/shard-scaling.json"
    echo "== bench smoke: fig11 (reduced iterations) =="
    cargo run --release --quiet -- bench fig11 --batches 6 --json > "$out/fig11.json"
    echo "== bench smoke: tier-sweep (reduced iterations) =="
    cargo run --release --quiet -- bench tier-sweep --batches 6 --json > "$out/tier-sweep.json"
    echo "== bench smoke: tenant-interference (reduced iterations) =="
    cargo run --release --quiet -- bench tenant-interference --batches 6 --json > "$out/tenant-interference.json"
    echo "== bench smoke: serve-latency (reduced iterations) =="
    cargo run --release --quiet -- bench serve-latency --batches 6 --json > "$out/serve-latency.json"
    echo "== bench smoke: fault-sweep (reduced iterations) =="
    cargo run --release --quiet -- bench fault-sweep --batches 6 --json > "$out/fault-sweep.json"
    echo "== bench smoke: engine-throughput (reduced iterations) =="
    cargo run --release --quiet -- bench engine-throughput --batches 3 --json > "$out/engine-throughput.json"
    # every bench entry point that exports a repo-root BENCH file must
    # have written it, and each must parse as JSON
    for bench in BENCH_engine.json BENCH_tenancy.json BENCH_faults.json; do
      if [ ! -s "$bench" ]; then
        echo "!! bench smoke: missing or empty $bench" >&2
        exit 1
      fi
      if command -v python3 >/dev/null 2>&1; then
        python3 -c "import json,sys; json.load(open(sys.argv[1]))" "$bench" || {
          echo "!! bench smoke: $bench is not valid JSON" >&2
          exit 1
        }
      fi
      cp "$bench" "$out/$bench"
    done
    # one validated Perfetto trace per shipped topology family: solo
    # fabric, sharded, tiered, multi-tenant training, mixed serving
    for world in cxl sharded-cxl-2x tiered-cxl-10 multi-tenant-2 serve-mixed-2; do
      echo "== trace smoke: $world =="
      cargo run --release --quiet -- trace "$world" --batches 4 --out "$out/trace-$world.json"
    done
    for f in "$out"/*.json; do
      if [ ! -s "$f" ]; then
        echo "!! bench smoke: empty report $f" >&2
        exit 1
      fi
    done
    echo "== bench smoke reports in $out =="
  else
    echo "!! cargo not found: skipping bench smoke (install a rust toolchain)" >&2
  fi
fi

exit "$status"
