//! Testbed device parameters (paper Tables 1-2), loaded from
//! `configs/devices/testbed.toml` with a compiled-in default so the
//! simulator works without the file (and so tests pin Table 2's ratios).

use crate::util::tomlmini::Doc;
use std::path::Path;

/// One memory medium (DRAM / PMEM / SSD row of Table 2).
#[derive(Clone, Debug, PartialEq)]
pub struct MediaParams {
    pub read_ns: f64,
    pub write_ns: f64,
    /// Per-channel bandwidth, GB/s (== bytes/ns).
    pub read_gbps: f64,
    pub write_gbps: f64,
    pub channels: usize,
    /// Accesses a channel overlaps (latency hiding).
    pub queue_depth: usize,
    /// Read-after-write interference (PMEM only; 0 disables).
    pub raw_window_ns: u64,
    pub raw_mult: f64,
    /// GC write amplification on small random writes (SSD only).
    pub write_amp: f64,
}

#[derive(Clone, Debug, PartialEq)]
pub struct LinkParams {
    pub gbps: f64,
    pub hop_ns: f64,
    pub flit_bytes: u64,
    pub hops: usize,
}

#[derive(Clone, Debug, PartialEq)]
pub struct HostParams {
    pub sync_ns: f64,
    pub memcpy_setup_ns: f64,
    pub kernel_launch_ns: f64,
    pub per_vector_ns: f64,
    pub dram_cache_rows_frac: f64,
}

#[derive(Clone, Debug, PartialEq)]
pub struct GpuParams {
    pub speedup_vs_cpu: f64,
    pub power_w: f64,
    /// Board power while idle-waiting (integrated over batch gaps — the
    /// paper's energy savings come chiefly from finishing sooner).
    pub idle_w: f64,
}

#[derive(Clone, Debug, PartialEq)]
pub struct CompLogicParams {
    pub flops_per_ns: f64,
    pub power_w: f64,
}

#[derive(Clone, Debug, PartialEq)]
pub struct CkptLogicParams {
    pub dma_setup_ns: f64,
    pub power_w: f64,
    /// Fraction of the MLP parameters logged per checkpoint. All systems
    /// (baselines included) use Check-N-Run-style differential + quantized
    /// MLP checkpoints (the paper's ref [3] reports >10x size reduction),
    /// which is also the only payload size consistent with the paper's own
    /// Fig 12 checkpoint intervals under Table 2 bandwidth.
    pub mlp_log_frac: f64,
}

/// Dynamic + static energy coefficients (Fig 13 inputs).
#[derive(Clone, Debug, PartialEq)]
pub struct EnergyParams {
    pub dram_pj_per_byte: f64,
    pub pmem_read_pj_per_byte: f64,
    pub pmem_write_pj_per_byte: f64,
    pub ssd_pj_per_byte: f64,
    pub link_pj_per_byte: f64,
    pub host_cpu_power_w: f64,
    pub dram_static_w_per_gb: f64,
    pub pmem_static_w_per_gb: f64,
    pub ssd_static_w: f64,
}

/// Per-batch MLP times on the emulated GPU, microseconds:
/// (bmlp_fwd, bmlp_bwd, tmlp_fwd, tmlp_bwd).
pub type MlpTimesUs = [f64; 4];

#[derive(Clone, Debug, PartialEq)]
pub struct DeviceParams {
    pub dram: MediaParams,
    pub pmem: MediaParams,
    pub ssd: MediaParams,
    pub cxl_link: LinkParams,
    pub pcie_link: LinkParams,
    pub host: HostParams,
    pub gpu: GpuParams,
    pub comp_logic: CompLogicParams,
    pub ckpt_logic: CkptLogicParams,
    pub energy: EnergyParams,
    /// Fallback calibration table: model name -> MLP times.
    pub calibration: Vec<(String, MlpTimesUs)>,
}

impl DeviceParams {
    /// The checked-in testbed defaults (same numbers as
    /// `configs/devices/testbed.toml`); tests pin Table 2 ratios on this.
    pub fn builtin_default() -> DeviceParams {
        DeviceParams {
            dram: MediaParams {
                read_ns: 80.0,
                write_ns: 80.0,
                read_gbps: 19.2,
                write_gbps: 19.2,
                channels: 4,
                queue_depth: 16,
                raw_window_ns: 0,
                raw_mult: 1.0,
                write_amp: 1.0,
            },
            pmem: MediaParams {
                read_ns: 240.0,
                write_ns: 560.0,
                read_gbps: 11.52,
                write_gbps: 1.92,
                channels: 4,
                queue_depth: 4,
                raw_window_ns: 2_000_000,
                raw_mult: 2.2,
                write_amp: 1.0,
            },
            ssd: MediaParams {
                read_ns: 13_200.0,
                write_ns: 13_200.0,
                read_gbps: 0.384,
                write_gbps: 0.384,
                channels: 1,
                queue_depth: 8,
                raw_window_ns: 0,
                raw_mult: 1.0,
                write_amp: 2.5,
            },
            cxl_link: LinkParams {
                gbps: 64.0,
                hop_ns: 25.0,
                flit_bytes: 64,
                hops: 2,
            },
            pcie_link: LinkParams {
                gbps: 32.0,
                hop_ns: 500.0,
                flit_bytes: 256,
                hops: 1,
            },
            host: HostParams {
                sync_ns: 12_000.0,
                memcpy_setup_ns: 6_000.0,
                kernel_launch_ns: 8_000.0,
                per_vector_ns: 150.0,
                dram_cache_rows_frac: 0.02,
            },
            gpu: GpuParams {
                speedup_vs_cpu: 100.0,
                power_w: 320.0,
                idle_w: 100.0,
            },
            comp_logic: CompLogicParams {
                flops_per_ns: 64.0,
                power_w: 12.0,
            },
            ckpt_logic: CkptLogicParams {
                dma_setup_ns: 200.0,
                power_w: 4.0,
                mlp_log_frac: 0.25,
            },
            energy: EnergyParams {
                dram_pj_per_byte: 150.0,
                pmem_read_pj_per_byte: 400.0,
                pmem_write_pj_per_byte: 1800.0,
                ssd_pj_per_byte: 2500.0,
                link_pj_per_byte: 60.0,
                host_cpu_power_w: 150.0,
                dram_static_w_per_gb: 0.40,
                pmem_static_w_per_gb: 0.05,
                ssd_static_w: 5.0,
            },
            calibration: vec![
                ("rm1".into(), [240.0, 440.0, 180.0, 320.0]),
                ("rm2".into(), [240.0, 440.0, 280.0, 500.0]),
                ("rm3".into(), [600.0, 1080.0, 280.0, 500.0]),
                ("rm4".into(), [960.0, 1720.0, 280.0, 500.0]),
                ("rm_mini".into(), [3.0, 6.0, 2.0, 4.0]),
                ("rm_e2e".into(), [48.0, 88.0, 72.0, 128.0]),
            ],
        }
    }

    /// Load `configs/devices/testbed.toml`, falling back to the builtin
    /// defaults for any missing key.
    pub fn load(root: &Path) -> anyhow::Result<DeviceParams> {
        let path = root.join("configs/devices/testbed.toml");
        if !path.exists() {
            return Ok(Self::builtin_default());
        }
        let doc = Doc::load(&path)?;
        let mut p = Self::builtin_default();
        let media = |p: &mut MediaParams, pre: &str, doc: &Doc| {
            p.read_ns = doc.f64_or(&format!("{pre}.read_ns"), p.read_ns);
            p.write_ns = doc.f64_or(&format!("{pre}.write_ns"), p.write_ns);
            p.read_gbps = doc.f64_or(&format!("{pre}.read_gbps"), p.read_gbps);
            p.write_gbps = doc.f64_or(&format!("{pre}.write_gbps"), p.write_gbps);
            p.channels = doc.usize_or(&format!("{pre}.channels"), p.channels);
            p.queue_depth = doc.usize_or(&format!("{pre}.queue_depth"), p.queue_depth);
            p.raw_window_ns =
                doc.f64_or(&format!("{pre}.raw_window_ns"), p.raw_window_ns as f64) as u64;
            p.raw_mult = doc.f64_or(&format!("{pre}.raw_mult"), p.raw_mult);
            p.write_amp = doc.f64_or(&format!("{pre}.write_amp"), p.write_amp);
        };
        media(&mut p.dram, "dram", &doc);
        media(&mut p.pmem, "pmem", &doc);
        media(&mut p.ssd, "ssd", &doc);
        let link = |l: &mut LinkParams, pre: &str, doc: &Doc| {
            l.gbps = doc.f64_or(&format!("{pre}.gbps"), l.gbps);
            l.hop_ns = doc.f64_or(&format!("{pre}.hop_ns"), l.hop_ns);
            l.flit_bytes = doc.f64_or(&format!("{pre}.flit_bytes"), l.flit_bytes as f64) as u64;
            l.hops = doc.usize_or(&format!("{pre}.hops"), l.hops);
        };
        link(&mut p.cxl_link, "link.cxl", &doc);
        link(&mut p.pcie_link, "link.pcie", &doc);
        p.host.sync_ns = doc.f64_or("host.sync_ns", p.host.sync_ns);
        p.host.memcpy_setup_ns = doc.f64_or("host.memcpy_setup_ns", p.host.memcpy_setup_ns);
        p.host.kernel_launch_ns = doc.f64_or("host.kernel_launch_ns", p.host.kernel_launch_ns);
        p.host.per_vector_ns = doc.f64_or("host.per_vector_ns", p.host.per_vector_ns);
        p.host.dram_cache_rows_frac =
            doc.f64_or("host.dram_cache_rows_frac", p.host.dram_cache_rows_frac);
        p.gpu.speedup_vs_cpu = doc.f64_or("gpu.speedup_vs_cpu", p.gpu.speedup_vs_cpu);
        p.gpu.power_w = doc.f64_or("gpu.power_w", p.gpu.power_w);
        p.gpu.idle_w = doc.f64_or("gpu.idle_w", p.gpu.idle_w);
        p.comp_logic.flops_per_ns =
            doc.f64_or("comp_logic.flops_per_ns", p.comp_logic.flops_per_ns);
        p.comp_logic.power_w = doc.f64_or("comp_logic.power_w", p.comp_logic.power_w);
        p.ckpt_logic.dma_setup_ns =
            doc.f64_or("ckpt_logic.dma_setup_ns", p.ckpt_logic.dma_setup_ns);
        p.ckpt_logic.power_w = doc.f64_or("ckpt_logic.power_w", p.ckpt_logic.power_w);
        p.ckpt_logic.mlp_log_frac =
            doc.f64_or("ckpt_logic.mlp_log_frac", p.ckpt_logic.mlp_log_frac);
        let e = &mut p.energy;
        e.dram_pj_per_byte = doc.f64_or("energy.dram_pj_per_byte", e.dram_pj_per_byte);
        e.pmem_read_pj_per_byte =
            doc.f64_or("energy.pmem_read_pj_per_byte", e.pmem_read_pj_per_byte);
        e.pmem_write_pj_per_byte =
            doc.f64_or("energy.pmem_write_pj_per_byte", e.pmem_write_pj_per_byte);
        e.ssd_pj_per_byte = doc.f64_or("energy.ssd_pj_per_byte", e.ssd_pj_per_byte);
        e.link_pj_per_byte = doc.f64_or("energy.link_pj_per_byte", e.link_pj_per_byte);
        e.host_cpu_power_w = doc.f64_or("energy.host_cpu_power_w", e.host_cpu_power_w);
        e.dram_static_w_per_gb = doc.f64_or("energy.dram_static_w_per_gb", e.dram_static_w_per_gb);
        e.pmem_static_w_per_gb = doc.f64_or("energy.pmem_static_w_per_gb", e.pmem_static_w_per_gb);
        e.ssd_static_w = doc.f64_or("energy.ssd_static_w", e.ssd_static_w);
        // calibration rows: calibration.<model> = [f, b, tf, tb] (us)
        for (key, val) in &doc.entries {
            if let Some(name) = key.strip_prefix("calibration.") {
                if let Some(arr) = val.as_usize_arr() {
                    if arr.len() == 4 {
                        let t: MlpTimesUs =
                            [arr[0] as f64, arr[1] as f64, arr[2] as f64, arr[3] as f64];
                        if let Some(row) = p.calibration.iter_mut().find(|(n, _)| n == name) {
                            row.1 = t;
                        } else {
                            p.calibration.push((name.to_string(), t));
                        }
                    }
                }
            }
        }
        Ok(p)
    }

    /// MLP times for `model`, preferring `artifacts/calibration.json`
    /// (written by `trainingcxl calibrate`) over the static table.
    pub fn mlp_times_us(&self, root: &Path, model: &str) -> Option<MlpTimesUs> {
        if let Ok(text) = std::fs::read_to_string(root.join("artifacts/calibration.json")) {
            if let Ok(j) = crate::util::json::Json::parse(&text) {
                if let Some(arr) = j.get(model).and_then(|v| v.as_arr()) {
                    if arr.len() == 4 {
                        let mut t = [0.0; 4];
                        for (i, v) in arr.iter().enumerate() {
                            t[i] = v.as_f64()?;
                        }
                        return Some(t);
                    }
                }
            }
        }
        self.calibration
            .iter()
            .find(|(n, _)| n == model)
            .map(|(_, t)| *t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::repo_root;

    #[test]
    fn toml_matches_builtin() {
        // the checked-in testbed.toml should agree with the builtin default
        let loaded = DeviceParams::load(&repo_root()).unwrap();
        let builtin = DeviceParams::builtin_default();
        assert_eq!(loaded.dram, builtin.dram);
        assert_eq!(loaded.pmem, builtin.pmem);
        assert_eq!(loaded.ssd, builtin.ssd);
        assert_eq!(loaded.cxl_link, builtin.cxl_link);
        assert_eq!(loaded.energy, builtin.energy);
    }

    #[test]
    fn calibration_lookup() {
        let p = DeviceParams::builtin_default();
        let t = p.mlp_times_us(std::path::Path::new("/nonexistent"), "rm1").unwrap();
        assert_eq!(t, [240.0, 440.0, 180.0, 320.0]);
        assert!(p.mlp_times_us(std::path::Path::new("/nonexistent"), "nope").is_none());
    }
}
